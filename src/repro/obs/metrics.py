"""MetricsHub: request-lifecycle metrics over the serving event stream.

The hub is a host-side registry of counters / gauges / histograms populated
from the SAME events a ``trace.TraceRecorder`` captures — it consumes event
dicts (schema.py), never engine or device state, so attaching metrics to a
serve adds exactly zero dispatches and zero host syncs (the zero-overhead
test in tests/test_obs.py asserts this for every policy x fuse x superstep
combination, and the ``repro.verify`` host-sync AST lint covers ``obs``).

Two ways to feed it, sharing one code path:

  live     — ``TraceRecorder(sinks=[hub])``: the recorder forwards every
             event (header included) to ``hub.observe`` as it is appended,
             so metrics are current while the engine serves.
  offline  — ``hub.ingest(trace)`` replays a loaded ``Trace``'s header +
             events + summary through the same ``observe``; a recorded
             JSONL file yields byte-identical metrics to the live serve
             that produced it (tested).

Per-request lifecycle (``RequestLifecycle``): arrival -> admit -> per-chunk
prefill -> first token -> per-token decode -> completion, all timestamped in
ENGINE-CLOCK TICKS (one scheduler step = one tick; a decode superstep's k
inner rounds advance the clock k ticks). Tick timestamps make every derived
metric deterministic for a seeded workload — which is what lets
``benchmarks/latency_guard.py`` hold p50/p99 latency baselines exactly.

Metric definitions (the glossary README "Observability" documents):

  TTFT        ticks from a request's TRUE arrival (the recorded injection
              step minus its ``arrival_offset`` — schema v5 records the
              offset so arrivals landing mid-superstep are not batched at
              the superstep boundary) to the decode step that carried its
              first generated token.
  TPOT        tick gap between a request's consecutive generated tokens
              (first token excluded; superstep inner rounds are 1 tick
              apart by construction).
  queue_wait  ticks from true arrival to admission.
  queue_depth / slots_busy   gauges stepped at every arrival / admit /
              completion; summarized time-weighted over the serve.
  valid-token fraction       valid prompt tokens over computed token slots
              across all prefill dispatches (the packing metric).
  dispatch mix               prefill / decode / fused dispatch counts plus
              superstep spans and the rounds they covered, derived from the
              event stream with the same closed-form rules the protocol
              lint checks against the engine's own counters.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

PERCENTILES = (50.0, 95.0, 99.0)


class Counter:
    """Monotonic count."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def merge(self, other: "Counter") -> "Counter":
        """Fleet aggregation: counts add."""
        self.value += other.value
        return self

    def to_dict(self) -> dict:
        return {"type": "counter", "value": self.value}

    def state_dict(self) -> dict:
        """Lossless export (``from_state`` round-trips exactly)."""
        return {"type": "counter", "name": self.name, "value": self.value}

    @classmethod
    def from_state(cls, d: dict) -> "Counter":
        c = cls(d["name"])
        c.value = d["value"]
        return c


class Gauge:
    """A stepped time series (tick, value): queue depth, slot occupancy.
    Summaries are time-weighted over [first tick, last tick] — each recorded
    value holds until the next change."""

    def __init__(self, name: str):
        self.name = name
        self.series: List[tuple] = []     # (tick, value), tick non-decreasing

    def set(self, tick: float, value: float) -> None:
        if self.series and self.series[-1][0] == tick:
            self.series[-1] = (tick, value)
        else:
            self.series.append((tick, value))

    @property
    def value(self) -> float:
        return self.series[-1][1] if self.series else 0.0

    def max(self) -> float:
        return max((v for _, v in self.series), default=0.0)

    def time_weighted_mean(self) -> float:
        if len(self.series) < 2:
            return self.value
        total, acc = 0.0, 0.0
        for (t0, v0), (t1, _v1) in zip(self.series, self.series[1:]):
            acc += v0 * (t1 - t0)
            total += t1 - t0
        return acc / total if total else self.value

    def merge(self, other: "Gauge") -> "Gauge":
        """Fleet aggregation by TICK INTERVAL: the merged series is the SUM
        of the two step functions over the union of their change ticks
        (each series reads 0 before its first sample). This is the correct
        semantics for per-replica queue depth / slot occupancy sharing one
        fleet clock — naive sample averaging would weight each replica's
        values by how often they *changed*, not how long they *held*."""
        if not other.series:
            return self
        if not self.series:
            self.series = [(t, v) for t, v in other.series]
            return self
        a, b = self.series, other.series
        ia = ib = 0
        va = vb = 0.0
        merged: List[tuple] = []
        for t in sorted({t for t, _ in a} | {t for t, _ in b}):
            while ia < len(a) and a[ia][0] <= t:
                va = a[ia][1]
                ia += 1
            while ib < len(b) and b[ib][0] <= t:
                vb = b[ib][1]
                ib += 1
            merged.append((t, va + vb))
        self.series = merged
        return self

    def to_dict(self) -> dict:
        return {"type": "gauge", "last": self.value, "max": self.max(),
                "mean": self.time_weighted_mean(),
                "samples": len(self.series)}

    def state_dict(self) -> dict:
        """Lossless export: the full stepped series, so a reloaded gauge
        merges and summarizes identically to the original."""
        return {"type": "gauge", "name": self.name,
                "series": [[t, v] for t, v in self.series]}

    @classmethod
    def from_state(cls, d: dict) -> "Gauge":
        g = cls(d["name"])
        g.series = [(t, v) for t, v in d["series"]]
        return g


class Histogram:
    """Exact sample store with numpy-matching percentile math (linear
    interpolation — ``np.percentile``'s default; the test pins equality)."""

    def __init__(self, name: str):
        self.name = name
        self.samples: List[float] = []

    def observe(self, value: float) -> None:
        self.samples.append(float(value))

    @property
    def count(self) -> int:
        return len(self.samples)

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples), q))

    def summary(self) -> dict:
        if not self.samples:
            return {"count": 0, "mean": 0.0, "min": 0.0, "max": 0.0,
                    **{f"p{q:g}": 0.0 for q in PERCENTILES}}
        a = np.asarray(self.samples)
        out = {"count": int(a.size), "mean": float(a.mean()),
               "min": float(a.min()), "max": float(a.max())}
        for q in PERCENTILES:
            out[f"p{q:g}"] = float(np.percentile(a, q))
        return out

    def merge(self, other: "Histogram") -> "Histogram":
        """Fleet aggregation is LOSSLESS: samples concatenate, so percentiles
        of a merged histogram are exactly ``np.percentile`` over the
        concatenated raw samples (no bucketing error to compound)."""
        self.samples.extend(other.samples)
        return self

    def to_dict(self) -> dict:
        return {"type": "histogram", **self.summary()}

    def state_dict(self) -> dict:
        """Lossless export: raw samples, not a summary."""
        return {"type": "histogram", "name": self.name,
                "samples": list(self.samples)}

    @classmethod
    def from_state(cls, d: dict) -> "Histogram":
        h = cls(d["name"])
        h.samples = [float(s) for s in d["samples"]]
        return h


@dataclass
class RequestLifecycle:
    """One request's timeline, every field in engine-clock ticks."""
    rid: int
    arrival: int                  # true arrival tick (injection - offset)
    injected: int                 # tick the engine actually saw it
    prompt_len: int
    max_new: int
    gid: Optional[int] = None     # fleet-global id (schema v7); == rid solo
    admit: Optional[int] = None
    slot: Optional[int] = None
    prefill_steps: List[int] = field(default_factory=list)
    first_token: Optional[int] = None
    last_token: Optional[int] = None
    n_tokens: int = 0
    complete: Optional[int] = None
    reason: Optional[str] = None

    @property
    def ttft(self) -> Optional[int]:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    def to_dict(self) -> dict:
        return {"rid": self.rid, "gid": self.gid, "arrival": self.arrival,
                "injected": self.injected, "prompt_len": self.prompt_len,
                "max_new": self.max_new, "admit": self.admit,
                "slot": self.slot, "prefill_steps": list(self.prefill_steps),
                "first_token": self.first_token,
                "last_token": self.last_token, "n_tokens": self.n_tokens,
                "complete": self.complete, "reason": self.reason,
                "ttft": self.ttft}


class MetricsHub:
    """Event-driven metrics registry + per-request lifecycle store."""

    def __init__(self):
        self.header: Optional[dict] = None
        self.engine_summary: Optional[dict] = None
        self.requests: Dict[int, RequestLifecycle] = {}
        self._metrics: Dict[str, object] = {}
        self._slot_rid: Dict[int, int] = {}
        self._queue_depth = 0
        self._slots_busy = 0
        self._superstep_ids: set = set()
        # terminal chaos outcomes land on ONE node's recorder (the lowest
        # alive id) but are fleet-scoped — keyed by gid for the rollup
        self.failed_gids: set = set()
        self.rejected_gids: set = set()

    # ---- registry ---------------------------------------------------------- #
    def _get(self, cls, name: str):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(Counter, name)

    def merge(self, other: "MetricsHub") -> "MetricsHub":
        """Merge another hub's metric REGISTRY into this one: counters add,
        histograms concatenate samples (percentiles stay exact), gauges sum
        as step functions over the fleet clock. Request lifecycles, header
        and engine summary are NOT merged — rids are per-engine, so
        ``repro.fleet.FleetMetrics`` keeps per-node hubs for request-level
        data and uses this only for the fleet-wide registry rollup."""
        for name, m in other._metrics.items():
            self._get(type(m), name).merge(m)
        return self

    def gauge(self, name: str) -> Gauge:
        return self._get(Gauge, name)

    def histogram(self, name: str) -> Histogram:
        return self._get(Histogram, name)

    # ---- event ingestion --------------------------------------------------- #
    def ingest(self, trace) -> "MetricsHub":
        """Replay a loaded ``trace.Trace`` through ``observe`` (header,
        events, summary) — the offline twin of the live sink path."""
        self.observe(trace.header)
        for ev in trace.events:
            self.observe(ev)
        if trace.summary is not None:
            self.observe(trace.summary)
        return self

    def observe(self, ev: dict) -> None:
        handler = getattr(self, f"_on_{ev['type']}", None)
        if handler is not None:
            handler(ev)

    def _on_header(self, ev: dict) -> None:
        self.header = ev

    def _on_request(self, ev: dict) -> None:
        step = int(ev["step"])
        arrival = step - int(ev.get("arrival_offset", 0))
        self.requests[ev["rid"]] = RequestLifecycle(
            rid=int(ev["rid"]), arrival=arrival, injected=step,
            prompt_len=int(ev["prompt_len"]), max_new=int(ev["max_new"]),
            gid=int(ev.get("gid", ev["rid"])))
        self.counter("requests_arrived").inc()
        self.histogram("prompt_len").observe(ev["prompt_len"])
        self._queue_depth += 1
        self.gauge("queue_depth").set(step, self._queue_depth)

    def _on_admit(self, ev: dict) -> None:
        step = int(ev["step"])
        for slot, rid, _plen in ev["wave"]:
            lc = self.requests.get(rid)
            if lc is not None:
                lc.admit = step
                lc.slot = int(slot)
                self.histogram("queue_wait_ticks").observe(step - lc.arrival)
            self._slot_rid[int(slot)] = int(rid)
            self._queue_depth -= 1
            self._slots_busy += 1
        self.counter("admission_waves").inc()
        self.gauge("queue_depth").set(step, self._queue_depth)
        self.gauge("slots_busy").set(step, self._slots_busy)

    def _on_prefill(self, ev: dict) -> None:
        step = int(ev["step"])
        chunk, valid = int(ev["chunk"]), int(ev["valid"])
        self.counter("prefill_valid_tokens").inc(valid)
        # computed token slots per dispatch: the packed grid shrinks to the
        # rows used; the unpacked grid is always max_slots rows; a
        # sequential (fallback) event stands for `valid` one-token
        # full-batch dispatches — the same rules engine.prefill_stats uses
        max_slots = int(self.header["serve"]["max_slots"]) if self.header \
            else len(ev["slots"])
        if ev.get("packed", False):
            self.counter("prefill_token_slots").inc(int(ev["rows"]) * chunk)
        elif self.header is not None and \
                self.header["serve"].get("prefill_mode") == "sequential":
            self.counter("prefill_token_slots").inc(max_slots * valid)
        else:
            self.counter("prefill_token_slots").inc(max_slots * chunk)
        if ev.get("fused", False):
            self.counter("fused_prefill_events").inc()
        else:
            self.counter("prefill_dispatches").inc()
        for slot in ev["slots"]:
            rid = self._slot_rid.get(int(slot))
            lc = self.requests.get(rid) if rid is not None else None
            if lc is not None:
                lc.prefill_steps.append(step)

    def _on_decode(self, ev: dict) -> None:
        step = int(ev["step"])
        sid = int(ev.get("superstep_id", -1))
        fused = bool(ev.get("fused", False))
        if fused:
            self.counter("fused_dispatches").inc()
        elif sid < 0:
            self.counter("decode_dispatches").inc()
        elif sid not in self._superstep_ids:
            self._superstep_ids.add(sid)
            self.counter("decode_dispatches").inc()
            self.counter("superstep_spans").inc()
        if sid >= 0:
            self.counter("superstep_rounds").inc()
        self.counter("tokens_generated").inc(len(ev["tokens"]))
        self.histogram("decode_occupancy").observe(ev["occupancy"])
        for rid, _tok in ev["tokens"]:
            lc = self.requests.get(rid)
            if lc is None:
                continue
            if lc.first_token is None:
                lc.first_token = step
                self.histogram("ttft_ticks").observe(step - lc.arrival)
            else:
                self.histogram("tpot_ticks").observe(step - lc.last_token)
            lc.last_token = step
            lc.n_tokens += 1

    def _on_complete(self, ev: dict) -> None:
        step = int(ev["step"])
        rid = int(ev["rid"])
        lc = self.requests.get(rid)
        if lc is not None:
            lc.complete = step
            lc.reason = ev["reason"]
            if lc.slot is not None and self._slot_rid.get(lc.slot) == rid:
                del self._slot_rid[lc.slot]
        self.counter("requests_completed").inc()
        self.counter(f"completed_{ev['reason']}").inc()
        self._slots_busy -= 1
        self.gauge("slots_busy").set(step, self._slots_busy)

    # ---- chaos events (schema v7, repro.chaos) ----------------------------- #
    def _on_fault(self, ev: dict) -> None:
        kind, phase, step = ev["kind"], ev["phase"], int(ev["step"])
        if phase == "begin":
            self.counter(f"faults_{kind}").inc()
            if kind == "node_crash":
                # the node is gone: its queued/resident load leaves the
                # fleet's merged gauges at the crash tick (the failover
                # re-arrivals re-enter on surviving nodes' hubs)
                self.counter("crash_inflight").inc(int(ev.get("inflight", 0)))
                self._queue_depth = 0
                self._slots_busy = 0
                self.gauge("queue_depth").set(step, 0)
                self.gauge("slots_busy").set(step, 0)
        elif phase == "end" and "since" in ev:
            self.histogram(f"fault_window_{kind}").observe(
                step - int(ev["since"]))

    def _on_recover(self, ev: dict) -> None:
        # fires on the DESTINATION node's hub: failover landed here
        self.counter("requests_recovered").inc()
        self.counter("recovery_reprefill_tokens").inc(
            int(ev["reprefill_tokens"]))
        # schema v8: tokens seeded from a KV snapshot instead of paid for
        # again — reprefill (paid) + restored (saved) = from-zero cost
        self.counter("recovery_restored_tokens").inc(
            int(ev.get("restored_tokens", 0)))
        # downtime = crash tick -> the re-prefill re-entering service; the
        # per-gid MTTR-to-next-token joins this with the new lifecycle
        self.histogram("recovery_downtime_ticks").observe(
            int(ev["step"]) - int(ev["crash_step"]))
        self.histogram("recovery_retries").observe(int(ev["retry"]))

    # ---- snapshot events (schema v8, repro.chaos.snapshots) ---------------- #
    def _on_snapshot(self, ev: dict) -> None:
        # fires on the EXPORTING node's hub: one KV delta left for the store
        self.counter("snapshot_events").inc()
        self.counter("snapshot_bytes").inc(int(ev["bytes"]))
        self.counter("snapshot_rows").inc(
            int(ev["prefix_len"]) - int(ev.get("base", 0)))

    def _on_restore(self, ev: dict) -> None:
        # fires on the DESTINATION node's hub: a snapshot seeded a slot here
        self.counter("requests_restored").inc()
        self.counter("restore_bytes").inc(int(ev["bytes"]))
        self.histogram("restore_prefix_len").observe(int(ev["prefix_len"]))

    def _on_failed(self, ev: dict) -> None:
        self.counter("requests_failed").inc()
        self.counter(f"failed_{ev['reason']}").inc()
        self.failed_gids.add(int(ev["gid"]))

    def _on_reject(self, ev: dict) -> None:
        self.counter("requests_rejected").inc()
        self.counter(f"rejected_{ev['reason']}").inc()
        self.rejected_gids.add(int(ev["gid"]))

    def _on_summary(self, ev: dict) -> None:
        self.engine_summary = ev

    # ---- derived SLO report ------------------------------------------------ #
    def dispatch_mix(self) -> dict:
        """Event-derived dispatch accounting — same closed forms the
        protocol lint holds the engine's own counters to, so live counters
        and this mix cannot silently diverge."""
        supersteps = self.counter("superstep_spans").value
        return {
            "prefill": self.counter("prefill_dispatches").value,
            "decode": self.counter("decode_dispatches").value,
            "fused": self.counter("fused_dispatches").value,
            "total": (self.counter("prefill_dispatches").value
                      + self.counter("decode_dispatches").value
                      + self.counter("fused_dispatches").value),
            "superstep_spans": supersteps,
            "superstep_rounds": self.counter("superstep_rounds").value,
            # one blocking fetch per plain/fused decode resolve, one per
            # superstep span — i.e. per decode-family dispatch
            "host_syncs": (self.counter("decode_dispatches").value
                           + self.counter("fused_dispatches").value),
        }

    def completed_gids(self) -> set:
        """Global ids of requests that COMPLETED on this node — the
        per-node input to the fleet's exactly-once / goodput rollup."""
        return {lc.gid for lc in self.requests.values()
                if lc.complete is not None and lc.gid is not None}

    def arrived_gids(self) -> set:
        return {lc.gid for lc in self.requests.values()
                if lc.gid is not None}

    def chaos_summary(self) -> Optional[dict]:
        """Per-node chaos report, or None for a fault-free serve."""
        names = [n for n in self._metrics
                 if n.startswith(("faults_", "failed_", "rejected_"))
                 or n in ("requests_recovered", "requests_failed",
                          "requests_rejected", "crash_inflight")]
        if not names:
            return None
        return {
            "faults": {n[len("faults_"):]: self._metrics[n].value
                       for n in names if n.startswith("faults_")},
            "fault_windows": {
                n[len("fault_window_"):]: self._metrics[n].summary()
                for n in self._metrics if n.startswith("fault_window_")},
            "recovered": self.counter("requests_recovered").value,
            "failed": self.counter("requests_failed").value,
            "rejected": self.counter("requests_rejected").value,
            "crash_inflight": self.counter("crash_inflight").value,
            "reprefill_tokens":
                self.counter("recovery_reprefill_tokens").value,
            "recovery_downtime_ticks":
                self.histogram("recovery_downtime_ticks").summary(),
            "snapshots": self.snapshot_summary(),
        }

    def snapshot_summary(self) -> dict:
        """KV-snapshot accounting (all-zero when snapshots are off):
        export volume, restore hit rate over recoveries, and the
        saved-vs-paid re-prefill split (saved = restored from snapshots,
        paid = actually re-prefilled; their sum is the from-zero cost)."""
        recovered = self.counter("requests_recovered").value
        restores = self.counter("requests_restored").value
        return {
            "events": self.counter("snapshot_events").value,
            "bytes": self.counter("snapshot_bytes").value,
            "rows": self.counter("snapshot_rows").value,
            "restores": restores,
            "restore_bytes": self.counter("restore_bytes").value,
            "restore_hit_rate": (restores / recovered if recovered
                                 else 0.0),
            "saved_tokens":
                self.counter("recovery_restored_tokens").value,
            "paid_tokens":
                self.counter("recovery_reprefill_tokens").value,
            "restore_prefix_len":
                self.histogram("restore_prefix_len").summary(),
        }

    def valid_token_fraction(self) -> float:
        slots = self.counter("prefill_token_slots").value
        if not slots:
            return 1.0
        return self.counter("prefill_valid_tokens").value / slots

    def summary(self) -> dict:
        """The JSON-serializable SLO report."""
        serve = dict(self.header.get("serve", {})) if self.header else {}
        return {
            "policy": serve.get("policy"),
            "serve": serve,
            "arch": self.header.get("arch") if self.header else None,
            "requests": {
                "arrived": self.counter("requests_arrived").value,
                "completed": self.counter("requests_completed").value,
                "tokens_generated": self.counter("tokens_generated").value,
                "reasons": {
                    r: self._metrics[f"completed_{r}"].value
                    for r in ("eos", "max_new", "cache_full")
                    if f"completed_{r}" in self._metrics},
            },
            "ttft_ticks": self.histogram("ttft_ticks").summary(),
            "tpot_ticks": self.histogram("tpot_ticks").summary(),
            "queue_wait_ticks": self.histogram("queue_wait_ticks").summary(),
            "queue_depth": self.gauge("queue_depth").to_dict(),
            "slots_busy": self.gauge("slots_busy").to_dict(),
            "decode_occupancy": self.histogram("decode_occupancy").summary(),
            "prompt_len": self.histogram("prompt_len").summary(),
            "valid_token_fraction": self.valid_token_fraction(),
            "dispatch_mix": self.dispatch_mix(),
            "chaos": self.chaos_summary(),
            # per-step-kind mix the scheduler ticked (serialized /
            # overlapped / fused / superstep / ...), when recorded
            "sched_stats": dict(self.engine_summary["sched_stats"])
            if self.engine_summary and "sched_stats" in self.engine_summary
            else None,
            # the engine's own counters, verbatim (cross-checkable against
            # dispatch_mix; the protocol lint enforces agreement)
            "engine": {
                k: self.engine_summary[k]
                for k in ("dispatch_counts", "host_syncs", "prefill_stats",
                          "decode_deferrals", "superstep_tokens")
                if k in self.engine_summary}
            if self.engine_summary else None,
        }

    def to_dict(self) -> dict:
        """Full export: the SLO summary, every registered metric, and every
        request lifecycle."""
        return {
            "summary": self.summary(),
            "metrics": {name: m.to_dict()
                        for name, m in sorted(self._metrics.items())},
            "requests": [self.requests[r].to_dict()
                         for r in sorted(self.requests)],
        }


__all__ = ["Counter", "Gauge", "Histogram", "MetricsHub",
           "RequestLifecycle", "PERCENTILES"]
