"""Chrome/Perfetto trace-event export for served workloads and replays.

Renders a recorded serving trace (``trace.Trace``) — and optionally its
simulator replay — into the Trace Event Format JSON that chrome://tracing
and https://ui.perfetto.dev load directly (``write_chrome_trace``).

Engine timeline (pid "serving engine", timebase: 1 engine-clock tick =
``TICK_US`` trace microseconds; several dispatches issued within one tick
subdivide it in issue order):

  NPU prefill   one slice per standalone prefill chunk dispatch
  PIM decode    one slice per plain decode dispatch; a decode SUPERSTEP is
                one outer slice spanning its k ticks (the dispatch) with k
                nested 1-tick round slices (the ``lax.scan`` iterations)
  fused step    a fused prefill+decode pair renders as ONE slice — it was
                one device program, so the timeline shows one span, not two
  host fetch    one "resolve" slice per blocking device->host fetch, tied
                to its dispatch slice by a flow arrow (the double-buffered
                fetch window; a superstep's k rounds share one resolve —
                the amortization is visible as k slices feeding one flow)
  slots         per-slot lanes: one slice per resident request, admit ->
                completion
  counters      queue_depth / slots_busy counter tracks stepped at every
                arrival, admission and completion

Every slice that stands for a host dispatch carries ``cat="dispatch"`` —
the test suite (and the ``launch.stats`` coverage check) counts them
against the trace summary's dispatch totals, so the timeline provably
covers every recorded dispatch. Superstep inner rounds are ``cat="round"``
(k rounds, one dispatch), host resolves ``cat="fetch"``.

Simulator timeline (``sim_events``, pid "simulator"): every
``SimResult.trace`` span (start, end, unit, name, tag) — per-core MU/VU/DMA
engines and the PIM array — becomes a slice on its unit's track, so a
``TraceReplayer`` replay of the same trace (merged fused groups and
pipelined superstep spans included) drops into the SAME trace.json beside
the engine timeline.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

TICK_US = 1_000.0      # one engine-clock tick, in trace microseconds
PID_ENGINE = 1
PID_SLOTS = 2
PID_SIM = 3

# fleet export (``fleet_events``): one process GROUP per node, pids strided
# so Perfetto sorts node 0's engine/slots/sim tracks together, node 1's
# next, ... with the fleet-level counter process on top
PID_FLEET = 9
NODE_PID_STRIDE = 10

_TID_PREFILL = 1
_TID_DECODE = 2
_TID_FUSED = 3
_TID_FETCH = 4


def fleet_node_pids(node: int) -> tuple:
    """(engine, slots, sim) pids for one fleet node's track group."""
    base = NODE_PID_STRIDE * (int(node) + 1)
    return base, base + 1, base + 2


def _meta(pid: int, name: str, tid: Optional[int] = None,
          tname: Optional[str] = None, sort: Optional[int] = None) -> List[dict]:
    out = [{"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
            "args": {"name": name}}]
    if tid is not None:
        out.append({"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
                    "args": {"name": tname}})
        out.append({"ph": "M", "pid": pid, "tid": tid,
                    "name": "thread_sort_index",
                    "args": {"sort_index": sort if sort is not None else tid}})
    return out


def _slice(name: str, ts: float, dur: float, tid: int, *, pid: int = PID_ENGINE,
           cat: str = "dispatch", args: Optional[dict] = None) -> dict:
    ev = {"ph": "X", "name": name, "cat": cat, "ts": ts, "dur": dur,
          "pid": pid, "tid": tid}
    if args:
        ev["args"] = args
    return ev


class _TickLayout:
    """Sequential layout of the dispatches issued within one engine tick:
    the n-th dispatch of a tick occupies the n-th equal sub-window, in
    event order (the order the host issued them)."""

    def __init__(self):
        self._counts: Dict[int, int] = {}     # step -> dispatches recorded

    def place(self, step: int) -> int:
        i = self._counts.get(step, 0)
        self._counts[step] = i + 1
        return i

    def window(self, step: int, i: int) -> tuple:
        n = max(self._counts.get(step, 1), 1)
        width = TICK_US / n
        return step * TICK_US + i * width, width


def engine_events(trace, *, pid_engine: int = PID_ENGINE,
                  pid_slots: int = PID_SLOTS,
                  label: str = "serving engine",
                  slots_label: str = "slots") -> List[dict]:
    """Trace-event list for one recorded serving trace. ``pid_engine`` /
    ``pid_slots`` relocate the track group so ``fleet_events`` can lay N
    replicas' timelines side by side in one trace.json."""
    events: List[dict] = []
    events += _meta(pid_engine, label, _TID_PREFILL, "NPU prefill")
    events += _meta(pid_engine, label, _TID_DECODE, "PIM decode")
    events += _meta(pid_engine, label, _TID_FUSED,
                    "fused step (NPU+PIM)")
    events += _meta(pid_engine, label, _TID_FETCH, "host fetch")
    events += _meta(pid_slots, slots_label)

    # pass 1: count dispatch slices per (step, track) so co-issued work
    # subdivides its tick; fused pairs place ONE slice, superstep rounds
    # place on their own ticks
    layouts = {t: _TickLayout() for t in (_TID_PREFILL, _TID_DECODE,
                                          _TID_FUSED)}
    placed: List[tuple] = []      # (event, tid, step, slot_index)
    fused_decode_seen = set()     # steps whose fused pair is already placed
    superstep_rounds: Dict[int, List[dict]] = {}   # sid -> inner events
    for ev in trace.events:
        t = ev["type"]
        if t == "prefill":
            if ev.get("fused", False):
                continue          # the decode twin places the fused slice
            tid = _TID_PREFILL
        elif t == "decode":
            sid = int(ev.get("superstep_id", -1))
            if sid >= 0:
                superstep_rounds.setdefault(sid, []).append(ev)
                continue          # placed after the span is known
            tid = _TID_FUSED if ev.get("fused", False) else _TID_DECODE
        else:
            continue
        step = int(ev["step"])
        placed.append((ev, tid, step, layouts[tid].place(step)))
    for sid, rounds in superstep_rounds.items():
        # the superstep dispatch slice claims the first inner round's tick
        step = int(rounds[0]["step"])
        placed.append((rounds, _TID_DECODE, step,
                       layouts[_TID_DECODE].place(step)))

    flow_id = 0
    for ev, tid, step, i in placed:
        if isinstance(ev, list):          # a superstep span
            rounds = ev
            ts, width = layouts[tid].window(step, i)
            k = int(rounds[0].get("superstep", len(rounds)))
            end = (int(rounds[-1]["step"]) + 1) * TICK_US
            events.append(_slice(
                f"superstep x{k}", ts, end - ts, tid, pid=pid_engine,
                args={"step": step, "k": k, "rounds": len(rounds),
                      "superstep_id": int(rounds[0]["superstep_id"])}))
            for r in rounds:
                rts = int(r["step"]) * TICK_US
                events.append(_slice(
                    "decode round", max(rts, ts), TICK_US - max(ts - rts, 0),
                    tid, pid=pid_engine, cat="round",
                    args={"step": int(r["step"]),
                          "occupancy": int(r["occupancy"]),
                          "tokens": len(r["tokens"])}))
            flow_id += 1
            events += _fetch(flow_id, ts, end, tid,
                             {"kind": "superstep", "rounds": len(rounds)},
                             pid=pid_engine)
            continue
        ts, width = layouts[tid].window(step, i)
        if ev["type"] == "prefill":
            name = "prefill (packed)" if ev.get("packed") else "prefill"
            events.append(_slice(
                name, ts, width, tid, pid=pid_engine,
                args={"step": step, "offset": int(ev["offset"]),
                      "chunk": int(ev["chunk"]), "valid": int(ev["valid"]),
                      "kv": int(ev["kv"]), "rows": int(ev.get("rows", 0)),
                      "slots": list(ev["slots"]),
                      "overlap": bool(ev.get("overlap", False))}))
            continue
        if tid == _TID_FUSED:
            if step in fused_decode_seen:
                continue
            fused_decode_seen.add(step)
            name, kind = "fused prefill+decode", "fused"
        else:
            name, kind = "decode", "decode"
        events.append(_slice(
            name, ts, width, tid, pid=pid_engine,
            args={"step": step, "occupancy": int(ev["occupancy"]),
                  "tokens": len(ev["tokens"]),
                  "overlap": bool(ev.get("overlap", False))}))
        flow_id += 1
        events += _fetch(flow_id, ts, ts + width, tid, {"kind": kind},
                         pid=pid_engine)

    events += _lifecycle_events(trace, pid_engine=pid_engine,
                                pid_slots=pid_slots, slots_label=slots_label)
    return events


def _fetch(flow_id: int, dispatch_ts: float, resolve_end: float,
           dispatch_tid: int, args: dict,
           pid: int = PID_ENGINE) -> List[dict]:
    """The async-fetch flow: a flow arrow from the dispatch slice to the
    blocking resolve slice on the host-fetch track (one per host sync)."""
    rdur = TICK_US / 8
    rts = resolve_end - rdur
    return [
        {"ph": "s", "name": "fetch", "cat": "fetch", "id": flow_id,
         "pid": pid, "tid": dispatch_tid, "ts": dispatch_ts},
        _slice("resolve", rts, rdur, _TID_FETCH, pid=pid, cat="fetch",
               args=args),
        {"ph": "f", "name": "fetch", "cat": "fetch", "id": flow_id,
         "bp": "e", "pid": pid, "tid": _TID_FETCH, "ts": rts},
    ]


def _lifecycle_events(trace, *, pid_engine: int = PID_ENGINE,
                      pid_slots: int = PID_SLOTS,
                      slots_label: str = "slots") -> List[dict]:
    """Per-slot residency slices + queue/occupancy counter tracks."""
    events: List[dict] = []
    admit_step: Dict[int, tuple] = {}     # rid -> (slot, step, plen)
    arrival: Dict[int, int] = {}
    queue_depth, slots_busy = 0, 0
    horizon = 0

    def counters(step: int) -> None:
        events.append({"ph": "C", "name": "queue_depth", "pid": pid_engine,
                       "tid": 0, "ts": step * TICK_US,
                       "args": {"queued": queue_depth}})
        events.append({"ph": "C", "name": "slots_busy", "pid": pid_engine,
                       "tid": 0, "ts": step * TICK_US,
                       "args": {"busy": slots_busy}})

    for ev in trace.events:
        t = ev["type"]
        step = int(ev["step"])
        horizon = max(horizon, step)
        if t == "request":
            arrival[ev["rid"]] = step - int(ev.get("arrival_offset", 0))
            queue_depth += 1
            counters(step)
        elif t == "admit":
            for slot, rid, plen in ev["wave"]:
                admit_step[rid] = (int(slot), step, int(plen))
                queue_depth -= 1
                slots_busy += 1
            counters(step)
        elif t == "complete":
            rid = int(ev["rid"])
            slots_busy -= 1
            counters(step)
            if rid in admit_step:
                slot, s0, plen = admit_step.pop(rid)
                events.append(_slice(
                    f"rid {rid}", s0 * TICK_US, (step + 1 - s0) * TICK_US,
                    slot, pid=pid_slots, cat="request",
                    args={"rid": rid, "prompt_len": plen,
                          "queue_wait": s0 - arrival.get(rid, s0),
                          "reason": ev["reason"],
                          "n_generated": int(ev["n_generated"])}))
    # requests still resident at end-of-trace close at the horizon
    for rid, (slot, s0, plen) in admit_step.items():
        events.append(_slice(
            f"rid {rid}", s0 * TICK_US, (horizon + 1 - s0) * TICK_US, slot,
            pid=pid_slots, cat="request",
            args={"rid": rid, "prompt_len": plen, "reason": "open"}))
    for slot in sorted({e["tid"] for e in events
                        if e.get("pid") == pid_slots and e["ph"] == "X"}):
        events += _meta(pid_slots, slots_label, slot, f"slot {slot}")
    return events


def sim_events(result, *, scale: float = 1e6,
               pid: int = PID_SIM, name: str = "simulator") -> List[dict]:
    """Trace-event list for a ``SimResult`` recorded with
    ``SimConfig(trace=True)`` — one slice per command span on its execution
    unit's track (per-core MU/VU/DMA engines, the PIM array). ``scale``
    converts simulator seconds to trace microseconds."""
    if not result.trace:
        raise ValueError("SimResult has no event trace; run the simulator "
                         "with SimConfig(trace=True)")
    events: List[dict] = _meta(pid, name)
    units = sorted({u for _s, _e, u, _n, _t in result.trace})
    tids = {u: i + 1 for i, u in enumerate(units)}
    for u in units:
        events += _meta(pid, name, tids[u], u)
    for s, e, u, cname, tag in result.trace:
        events.append(_slice(cname, s * scale, max(e - s, 0.0) * scale,
                             tids[u], pid=pid, cat="sim",
                             args={"unit": u, "tag": tag}))
    return events


def dispatch_slices(events: List[dict], pid: int = PID_ENGINE) -> List[dict]:
    """The slices standing for host dispatches (the coverage contract:
    exactly one per dispatch the trace summary counts). ``pid`` selects
    which node's engine track to count in a fleet export."""
    return [e for e in events if e["ph"] == "X" and e.get("cat") == "dispatch"
            and e.get("pid") == pid]


def fleet_events(traces: Dict[int, object],
                 replays: Optional[Dict[int, object]] = None) -> List[dict]:
    """One trace.json for a whole fleet: a process group per node (engine
    dispatch/fetch lanes, slot lanes, and — when ``replays`` carries that
    node's ``SimResult`` — its simulator tracks), topped by a fleet-level
    queue-depth counter summed over all replicas. Idle replicas show up as
    empty tracks next to busy ones — routing pathologies at a glance.

    ``traces`` maps node_id -> ``trace.Trace``; every node shares the fleet
    global tick, so slices line up across track groups without shifting."""
    from repro.obs.metrics import Gauge

    events: List[dict] = []
    events += _meta(PID_FLEET, "fleet")
    fleet_queue = Gauge("fleet_queue_depth")
    for node in sorted(traces):
        trace = traces[node]
        pid_engine, pid_slots, pid_sim = fleet_node_pids(node)
        events += engine_events(trace, pid_engine=pid_engine,
                                pid_slots=pid_slots,
                                label=f"node {node} · serving engine",
                                slots_label=f"node {node} · slots")
        if replays and node in replays and replays[node] is not None:
            events += sim_events(replays[node], pid=pid_sim,
                                 name=f"node {node} · simulator")
        # per-node queue-depth step function off the same lifecycle events
        # the per-node counter tracks render; merging sums over the fleet
        # clock (exactly Gauge.merge semantics)
        g = Gauge(f"node{node}")
        depth = 0
        for ev in trace.events:
            if ev["type"] == "request":
                depth += 1
            elif ev["type"] == "admit":
                depth -= len(ev["wave"])
            else:
                continue
            g.set(int(ev["step"]), depth)
        fleet_queue.merge(g)
    for t, v in fleet_queue.series:
        events.append({"ph": "C", "name": "fleet_queue_depth",
                       "pid": PID_FLEET, "tid": 0, "ts": t * TICK_US,
                       "args": {"queued": v}})
    return events


def write_chrome_trace(path, events: List[dict]) -> None:
    """Write a Perfetto/chrome://tracing-loadable trace.json."""
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)


__all__ = ["TICK_US", "PID_ENGINE", "PID_SLOTS", "PID_SIM", "PID_FLEET",
           "NODE_PID_STRIDE", "fleet_node_pids", "engine_events",
           "sim_events", "fleet_events", "dispatch_slices",
           "write_chrome_trace"]
