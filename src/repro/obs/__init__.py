"""repro.obs — serving observability: metrics, SLO summaries, timelines.

Host-side only, by construction: everything in this package consumes the
event dicts a ``trace.TraceRecorder`` emits (live, via ``sinks=``) or a
recorded ``trace.Trace`` (offline) — never engine or device state — so
metrics collection adds ZERO dispatches and ZERO host syncs to a serve.
The ``repro.verify`` host-sync AST lint scans this package along with
serve/sched, and the zero-overhead test pins dispatch/host-sync counts
metrics-on vs metrics-off for every policy.

  metrics   ``MetricsHub``: counter/gauge/histogram registry, per-request
            lifecycle timelines (arrival -> admit -> prefill chunks ->
            first token -> per-token decode -> completion), and the derived
            SLO summary (p50/p95/p99 TTFT & TPOT in engine-clock ticks,
            queue depth, slot occupancy, valid-token fraction, dispatch
            mix) — JSON-serializable.
  timeline  Chrome/Perfetto trace-event export: dispatch spans (fused
            pairs as one slice, supersteps as nested round slices),
            async-fetch flows, per-slot request lanes, queue-depth
            counters, and simulator-replay NPU/PIM stream spans, into one
            ``trace.json``.

CLI: ``python -m repro.launch.stats <trace.jsonl>`` emits the metrics
report and timeline for any recorded trace;
``benchmarks/latency_guard.py`` holds p50/p99 TTFT/TPOT to a committed
baseline in CI.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsHub,
                               PERCENTILES, RequestLifecycle)
from repro.obs.timeline import (NODE_PID_STRIDE, PID_ENGINE, PID_FLEET,
                                PID_SIM, PID_SLOTS, TICK_US, dispatch_slices,
                                engine_events, fleet_events, fleet_node_pids,
                                sim_events, write_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsHub", "PERCENTILES",
    "RequestLifecycle",
    "NODE_PID_STRIDE", "PID_ENGINE", "PID_FLEET", "PID_SIM", "PID_SLOTS",
    "TICK_US", "dispatch_slices", "engine_events", "fleet_events",
    "fleet_node_pids", "sim_events", "write_chrome_trace",
]
