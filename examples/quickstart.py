"""Quickstart: train a ~100M-param GPT-2-M-family model for a few hundred
steps on the byte-level corpus (this repo's own source code), checkpointing
along the way, then sample from it.

    PYTHONPATH=src python examples/quickstart.py [--steps 300] [--small]

--small uses the reduced config (seconds on CPU); the default GPT-2-M-width
config is the "real" ~100M driver (minutes on CPU).
"""
import argparse
import dataclasses
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import ByteCorpus
from repro.models import transformer as T
from repro.models.params import init_params, param_count
from repro.optim import adamw_init, linear_warmup_cosine
from repro.serve import ServeConfig, ServeEngine
from repro.train import TrainStepConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_arch("gpt2-m")
    if args.small:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, vocab_size=256)
    else:
        # byte-level GPT-2-M-family: ~100M params at vocab=256
        cfg = dataclasses.replace(cfg, vocab_size=256, num_layers=12,
                                  remat="none")
    root = os.path.join(os.path.dirname(__file__), "..", "src")
    data = ByteCorpus(root, args.seq, args.batch)

    defs = T.param_defs(cfg)
    print(f"model: {cfg.name} ({param_count(defs):,} params, "
          f"{cfg.num_layers}L d{cfg.d_model})")
    params = init_params(defs, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, TrainStepConfig(
        learning_rate=linear_warmup_cosine(3e-4, 30, args.steps))))

    ckdir = tempfile.mkdtemp(prefix="quickstart_ck_")
    mgr = CheckpointManager(ckdir)
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        if i % 25 == 0:
            print(f"step {i:4d}  loss {float(m['loss']):.3f}  "
                  f"({(time.time()-t0)/(i+1)*1e3:.0f} ms/step)")
        if i and i % 100 == 0:
            mgr.save(i, {"params": params, "opt": opt})
    mgr.wait()
    print(f"trained {args.steps} steps; checkpoints in {ckdir}")

    # sample: ASCII continuation of a source-code prompt
    prompt = b"def forward("
    eng = ServeEngine(cfg, params, ServeConfig(max_slots=1, max_len=args.seq,
                                               temperature=0.8))
    eng.add_request(np.frombuffer(prompt, np.uint8), max_new_tokens=48)
    out = list(eng.run_until_done().values())[0]
    text = bytes(t % 256 for t in out).decode("utf8", errors="replace")
    print(f"sample: {prompt.decode()!r} -> {text!r}")


if __name__ == "__main__":
    main()
