"""Policy bake-off: serve -> trace -> replay under every sched policy.

Serves one mixed-arrival workload on the llama3.2-1b smoke config under
``serial``, ``interleaved`` and ``pim_aware`` step composition, proves the
greedy tokens are identical (scheduling never changes numerics), and
replays each recorded trace through the simulator at full llama3.2-1b dims
— the Fig. 7 claim, measured on a *served* schedule: co-scheduling the
prefill sub-batch's NPU GEMMs with the resident batch's PIM FC mat-vecs
shortens the makespan and raises combined NPU+PIM utilization, while the
pim_aware gate only overlaps steps whose FC mappings land on different
engines.

    PYTHONPATH=src python examples/sched_compare.py
    PYTHONPATH=src python examples/sched_compare.py --requests 8 \
        --out sched_compare.json      # CI smoke artifact
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_arch
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve import ServeConfig, ServeEngine
from repro.trace import (TraceRecorder, TraceReplayer, drive,
                         poisson_arrivals, trace_to_commands)

POLICIES = ("serial", "interleaved", "pim_aware")
FULL_DIMS = (2048, 8192)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12,
                    help="approximate open-loop workload size")
    ap.add_argument("--out", default=None,
                    help="write the comparison as JSON (CI artifact)")
    args = ap.parse_args()

    cfg = get_arch("llama3.2-1b").reduced()
    full = get_arch("llama3.2-1b")
    params = init_params(T.param_defs(cfg), jax.random.PRNGKey(0))
    horizon = max(8, args.requests * 2)
    arrivals = poisson_arrivals(args.requests / horizon, horizon,
                                vocab=cfg.vocab_size, prompt_len=(2, 40),
                                max_new=(3, 8), seed=1)
    print(f"workload: {len(arrivals)} mixed-length requests over "
          f"{horizon} arrival steps\n")

    payload, results = {}, {}
    for pol in POLICIES:
        rec = TraceRecorder()
        eng = ServeEngine(cfg, params,
                          ServeConfig(max_slots=4, max_len=64,
                                      prefill_chunk=8, policy=pol,
                                      map_dims=FULL_DIMS),
                          recorder=rec)
        results[pol] = drive(eng, arrivals)
        rep = TraceReplayer().replay(
            trace_to_commands(rec.to_trace(), cfg=full))
        stats = eng.scheduler.stats
        payload[pol] = {
            "steps": eng.step_idx,
            "dispatch_counts": dict(eng.dispatch_counts),
            "host_syncs": eng.host_syncs,
            "async_fetches": eng.async_fetches,
            "scheduler_stats": dict(stats),
            "replay": rep.to_dict(),
        }
        print(f"{pol:>12}: {eng.step_idx} engine steps | "
              f"{eng.dispatch_counts['prefill']} prefill + "
              f"{eng.dispatch_counts['decode']} decode dispatches | "
              f"{stats['overlapped']} overlapped / "
              f"{stats['serialized']} serialized steps")
        print(f"{'':>12}  replay (full dims): "
              f"{rep.makespan * 1e3:.2f} ms makespan, "
              f"MU {rep.result.group_utilization('MU'):.1%} + "
              f"PIM {rep.result.group_utilization('PIM'):.1%}, "
              f"overlap gain {rep.overlap_stats['gain'] * 1e3:.2f} ms")

    same = results["serial"] == results["interleaved"] == \
        results["pim_aware"]
    assert same, "policies diverged numerically"
    speedup = (payload["serial"]["replay"]["breakdown"]["makespan"]
               / payload["interleaved"]["replay"]["breakdown"]["makespan"])
    print(f"\ngreedy tokens identical across policies "
          f"({sum(map(len, results['serial'].values()))} tokens); "
          f"interleaved replay speedup over serial: {speedup:.2f}x")

    if args.out:
        payload["equivalent_tokens"] = same
        payload["interleaved_speedup"] = speedup
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"comparison written to {args.out}")


if __name__ == "__main__":
    main()
