"""Fault tolerance demo: crash mid-training (injected), restart, resume from
the atomic checkpoint — the single-host rehearsal of the production
checkpoint/restart + elastic-resume path.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import subprocess
import sys
import tempfile

REPO = os.path.join(os.path.dirname(__file__), "..")
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def main():
    with tempfile.TemporaryDirectory() as ck:
        base = [sys.executable, "-m", "repro.launch.train",
                "--arch", "llama3.2-1b", "--smoke", "--steps", "80",
                "--batch", "4", "--seq", "32", "--ckpt-dir", ck,
                "--ckpt-every", "20", "--log-every", "20"]

        print("=== run 1: will crash at step 50 ===")
        r = subprocess.run(base + ["--fail-at-step", "50"], env=ENV,
                           capture_output=True, text=True)
        print(r.stdout)
        assert r.returncode == 17, "expected the injected crash"

        print("=== run 2: restart, resume from the checkpoint ===")
        r = subprocess.run(base, env=ENV, capture_output=True, text=True)
        print(r.stdout)
        assert r.returncode == 0
        assert "resumed" in r.stdout
        print("fault-tolerant restart verified.")


if __name__ == "__main__":
    main()
