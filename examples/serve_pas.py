"""End-to-end serving with live PAS decisions (the paper's core idea).

Serves batched requests through the continuous-batching engine while the
Algorithm-1 twin routes every step's FC work between the GEMM (MXU) path
and the streaming-GEMV (PIM-analogue) path, and prints the decisions.

    PYTHONPATH=src python examples/serve_pas.py
"""
import os
import sys

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_arch
from repro.core import FCConfig, IANUS_HW, TPU_V5E, route_fc_tpu
from repro.core.cost_model import pim_fc_time, pipelined_mu_time
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve import ServeConfig, ServeEngine


def main():
    cfg = get_arch("llama3.2-1b").reduced()
    params = init_params(T.param_defs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, ServeConfig(max_slots=4, max_len=96,
                                               prefill_chunk=16))

    rng = np.random.default_rng(0)
    for i in range(10):
        eng.add_request(rng.integers(0, cfg.vocab_size, rng.integers(2, 32)),
                        max_new_tokens=12)
    results = eng.run_until_done()
    print(f"served {len(results)} requests, "
          f"{sum(map(len, results.values()))} tokens")
    print(f"dispatches: {eng.dispatch_counts['prefill']} batched-prefill, "
          f"{eng.dispatch_counts['decode']} decode")
    # the paper's two phases, live from the engine's PAS log: summarization
    # (batched prompt chunks) routes GEMM, generation (small active batch)
    # routes GEMV — Algorithm 1 picks per phase, not per model
    print(f"{'phase':>14} {'tokens':>7} {'ffn_route':>10} {'gemv_path':>10}")
    for e in eng.pas_log[:8]:
        print(f"{e['phase']:>14} {e['tokens']:>7} {e['ffn_route']:>10} "
              f"{str(e['gemv_path']):>10}")
    gen = [e for e in eng.pas_log if e["phase"] == "generation"]
    gemv = sum(e["gemv_path"] for e in gen)
    print(f"...\nPAS: {gemv}/{len(gen)} generation steps took the "
          f"GEMV (PIM-analogue) path\n")

    # the Algorithm-1 crossover, on real model dims (llama3.2-1b FFN)
    full = get_arch("llama3.2-1b")
    fc = FCConfig(full.d_model, full.d_ff)
    print(f"Algorithm 1 crossover for the {full.name} FFN "
          f"({fc.d_in}x{fc.d_out}), TPU v5e engine model:")
    print(f"{'tokens':>8} {'gemm_us':>10} {'gemv_us':>10} {'route':>6}")
    for n in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512):
        g = pipelined_mu_time(TPU_V5E, n, fc) * 1e6
        v = pim_fc_time(TPU_V5E, n, fc) * 1e6
        print(f"{n:>8} {g:>10.1f} {v:>10.1f} "
              f"{route_fc_tpu(n, fc.d_in, fc.d_out):>6}")
    print("\n(IANUS engine model for comparison:)")
    for n in (1, 8, 16, 128):
        g = pipelined_mu_time(IANUS_HW, n, fc) * 1e6
        v = pim_fc_time(IANUS_HW, n, fc) * 1e6
        win = "PIM" if v < g else "MU"
        print(f"{n:>8} mu={g:>9.1f}us pim={v:>9.1f}us -> {win}")


if __name__ == "__main__":
    main()
