"""PAS explorer: reproduce the paper's scheduling figures interactively.

Runs the discrete-event simulator over GPT-2 XL generation and prints
(1) the Fig. 7 schedule as a unit-occupancy trace excerpt,
(2) the naive vs scheduled vs mapping ablation (Fig. 13 bars),
(3) the unified-memory exclusivity property checked on the trace.

    PYTHONPATH=src python examples/pas_explorer.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import paper_models as pm
from repro.core import IANUS_HW, PASPolicy, PIM, MU
from repro.sim import SimConfig, Simulator, graphs


def main():
    cfg = pm.GPT2_XL
    sim = Simulator(SimConfig(hw=IANUS_HW, issue_overhead=0.1e-6,
                              trace=True))
    pol = PASPolicy.paper()
    r = graphs.generation_step_latency(sim, cfg, 192, pol)

    print(f"GPT-2 XL generation step @ kv=192: {r.makespan*1e3:.2f} ms "
          f"(paper: 3.8 ms)\n")
    print("schedule excerpt (first 24 commands):")
    print(f"{'start_us':>9} {'end_us':>9} {'unit':>7}  command")
    for s, e, u, name, _tag in sorted(r.trace)[:24]:
        print(f"{s*1e6:>9.2f} {e*1e6:>9.2f} {u:>7}  {name}")

    # unified-memory exclusivity on the full trace
    onchip = ("k_transpose", "v_move")   # AM<->WM streaming path: exempt
    pim_iv = [(s, e) for s, e, u, *_ in r.trace if u == "PIM" and e > s]
    dma_iv = [(s, e) for s, e, u, n, _t in r.trace
              if u.startswith("DMA") and e > s
              and not n.startswith(onchip)]
    overlaps = sum(1 for ps, pe in pim_iv for ds, de in dma_iv
                   if max(ps, ds) < min(pe, de))
    print(f"\nunified-memory check: {overlaps} PIM/DMA overlaps "
          f"(must be 0) across {len(pim_iv)} PIM bursts, "
          f"{len(dma_iv)} DMA transfers")

    print("\nFig. 13 ablation (one generation step):")
    variants = [
        ("naive + QK/SV on PIM", False, PIM),
        ("scheduled + QK/SV on PIM", True, PIM),
        ("scheduled + QK/SV on MU (IANUS)", True, MU),
    ]
    base = None
    for name, scheduled, unit in variants:
        s = Simulator(SimConfig(hw=IANUS_HW, scheduled=scheduled,
                                issue_overhead=0.1e-6))
        p = dataclasses.replace(PASPolicy.paper(), scheduled=scheduled,
                                qk_sv_unit=unit)
        t = graphs.generation_step_latency(s, cfg, 192, p).makespan
        base = base or t
        print(f"  {name:34s} {t*1e3:6.2f} ms  ({base/t:.2f}x)")


if __name__ == "__main__":
    main()
