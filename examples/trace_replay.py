"""Capture -> lower -> replay: the PAS-log-to-simulator loop, closed.

Serves a mixed-length open-loop workload on the llama3.2-1b smoke config,
records the full trace (requests, admission waves, prefill dispatches,
decode steps, completions), lowers every served step to the PAS command
stream Algorithm 1 would schedule for that batch state, and replays it
through the discrete-event simulator:

  (a) a Fig. 10-style per-tag latency breakdown of the SERVED workload
      (exposed-DMA attribution), IANUS vs the NPU-MEM ablation,
  (b) a live-vs-offline FC routing divergence table: what the serving
      engine's route_fc_tpu chose per step vs what adaptive_map (Alg. 1)
      chose offline for the same FC and batch state.

    PYTHONPATH=src python examples/trace_replay.py
    PYTHONPATH=src python examples/trace_replay.py --requests 8 \
        --out breakdown.json      # CI smoke artifact
"""
import argparse
import json
import os
import sys
import tempfile

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_arch
from repro.core import NPU_MEM_HW
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serve import ServeConfig, ServeEngine
from repro.sim import SimConfig, Simulator
from repro.trace import (Trace, TraceRecorder, TraceReplayer,
                         baseline_comparison, divergence_report, drive,
                         poisson_arrivals, trace_to_commands)

TAGS = ("fc_mha", "ffn", "self_attn", "norm_res", "lm_head", "embed")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16,
                    help="approximate open-loop workload size")
    ap.add_argument("--trace-out", default=None,
                    help="keep the recorded JSONL trace at this path")
    ap.add_argument("--out", default=None,
                    help="write the replay breakdown as JSON (CI artifact)")
    args = ap.parse_args()

    # ---- capture: serve an open-loop mixed-length workload ----------------- #
    cfg = get_arch("llama3.2-1b").reduced()
    params = init_params(T.param_defs(cfg), jax.random.PRNGKey(0))
    rec = TraceRecorder()
    eng = ServeEngine(cfg, params,
                      ServeConfig(max_slots=4, max_len=96, prefill_chunk=16,
                                  eos_token=7),
                      recorder=rec)
    horizon = max(8, args.requests * 2)
    arrivals = poisson_arrivals(args.requests / horizon, horizon,
                                vocab=cfg.vocab_size, prompt_len=(2, 48),
                                max_new=(3, 12), seed=0)
    results = drive(eng, arrivals)
    print(f"served {len(results)} requests, "
          f"{sum(map(len, results.values()))} tokens | dispatches: "
          f"{eng.dispatch_counts['prefill']} prefill, "
          f"{eng.dispatch_counts['decode']} decode | "
          f"host syncs: {eng.host_syncs} (1 per decode step: sampling and "
          f"termination run inside the jitted step)")
    waste = eng.prefill_stats
    if waste["token_slots"]:
        print(f"prefill padding: {waste['valid_tokens']}/"
              f"{waste['token_slots']} token-slots useful "
              f"({100 * waste['valid_tokens'] / waste['token_slots']:.0f}%, "
              f"bucketed admission)")

    # ---- record -> serialize -> load (the JSONL round trip) ---------------- #
    path = args.trace_out or os.path.join(tempfile.gettempdir(),
                                          "trace_replay.jsonl")
    rec.save(path)
    trace = Trace.load(path)
    print(f"trace: {len(trace.events)} events "
          f"({len(trace.schedulable)} schedulable) -> {path}")

    # ---- lower + replay ---------------------------------------------------- #
    # Lowering is per target machine: the recorded schedule (occupancy, KV
    # lengths, chunking) comes from the trace; the command dims come from the
    # FULL llama3.2-1b config so Algorithm 1 sees paper-scale FCs (the smoke
    # model's 64x128 FCs are below every PIM crossover). The smoke-dims
    # lowering is kept for the routing-divergence diff, where live and
    # offline must see the same shapes.
    full = get_arch("llama3.2-1b")
    lowered = trace_to_commands(trace, cfg=full)
    lowered_npumem = trace_to_commands(trace, cfg=full, hw=NPU_MEM_HW)
    lowered_smoke = trace_to_commands(trace)
    rep = TraceReplayer().replay(lowered)
    rep_npumem = TraceReplayer(Simulator(SimConfig(
        hw=NPU_MEM_HW, trace=True, issue_overhead=0.1e-6))
    ).replay(lowered_npumem)

    print(f"\nreplay ({len(lowered)} served steps through the simulator, "
          f"full {full.name} dims):")
    print(f"  IANUS   {rep.makespan * 1e6:9.1f} us  "
          f"(summarization {rep.phase_time['summarization'] * 1e6:.1f}, "
          f"generation {rep.phase_time['generation'] * 1e6:.1f})")
    print(f"  NPU-MEM {rep_npumem.makespan * 1e6:9.1f} us  "
          f"-> speedup {rep_npumem.makespan / rep.makespan:.2f}x")
    print(f"  utilization: MU {rep.result.group_utilization('MU'):.0%}  "
          f"PIM {rep.result.group_utilization('PIM'):.0%}")

    print(f"\nFig. 10-style breakdown of the served workload "
          f"(exposed wall-time, us):")
    print(f"{'tag':>10} {'ianus':>9} {'npu-mem':>9} {'ratio':>6}")
    for tag in TAGS:
        a = rep.exposed_tags.get(tag, 0.0) * 1e6
        b = rep_npumem.exposed_tags.get(tag, 0.0) * 1e6
        ratio = b / a if a else float("nan")
        print(f"{tag:>10} {a:>9.1f} {b:>9.1f} {ratio:>6.2f}")

    print(f"\nFC routing divergence, live (route_fc_tpu, per phase, served "
          f"dims) vs offline (Algorithm 1, per command):")
    print(f"{'phase':>14} {'fc':>9} {'n':>5} {'live_gemv':>9} "
          f"{'offl_gemv':>9} {'agree':>6}")
    for row in divergence_report(lowered_smoke):
        print(f"{row['phase']:>14} {row['fc']:>9} {row['n']:>5} "
              f"{row['live_gemv']:>9} {row['offline_gemv']:>9} "
              f"{row['agreement']:>6.0%}")

    base = baseline_comparison(lowered, full)
    print(f"\nsame served schedule on the calibrated baselines: "
          f"A100 {base['a100']['total'] * 1e3:.1f} ms, "
          f"DFX {base['dfx']['total'] * 1e3:.1f} ms "
          f"(IANUS sim {rep.makespan * 1e3:.2f} ms)")

    if args.out:
        payload = {
            "requests": len(results),
            "dispatch_counts": eng.dispatch_counts,
            "host_syncs": eng.host_syncs,
            "prefill_stats": eng.prefill_stats,
            "ianus": rep.to_dict(),
            "npumem": rep_npumem.to_dict(),
            "baselines": base,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"breakdown written to {args.out}")


if __name__ == "__main__":
    main()
